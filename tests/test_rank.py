"""Accuracy-aware rank search (repro.rank): candidate space, accuracy
proxy, joint frontier search, v4 plan embedding."""

import json

import numpy as np
import pytest

from repro.configs import get_config
from repro.hw import get_target
from repro.rank import (
    FamilyFactorization,
    RankCandidate,
    RankSpace,
    candidate_proxy,
    clip_ranks,
    rank_search,
    reconstruction_proxy,
    reference_weight,
    vision_rank_space,
)


# -- candidate space ---------------------------------------------------


def test_clip_ranks_full_rank_bound():
    # each cut clipped to min(rank, prod(left), prod(right))
    assert clip_ranks((4, 6, 6, 3), 1000) == (4, 18, 3)
    assert clip_ranks((4, 6, 6, 3), 8) == (4, 8, 3)
    assert clip_ranks((24,), 5) == ()          # d=1 per side, one mode total
    assert clip_ranks((24, 18), 5) == (5,)     # degenerate TT: one cut


def test_family_factorization_validates():
    with pytest.raises(ValueError, match="do not factor"):
        FamilyFactorization("f", 24, 18, (4, 7), (6, 3), (4, 8, 3))
    with pytest.raises(ValueError, match="interior ranks"):
        FamilyFactorization("f", 24, 18, (4, 6), (6, 3), (4,))
    f = FamilyFactorization("f", 24, 18, (4, 6), (6, 3), (4, 8, 3))
    assert f.triple == ((4, 6), (6, 3), (4, 8, 3))
    assert f.dense_params == 24 * 18
    # cores: 1*4*4 + 4*6*8 + 8*6*3 + 3*3*1
    assert f.n_params == 16 + 192 + 144 + 9


def test_rank_space_frozen_first_dedup_budget():
    fams = [("proj", 64, 64, 2, 1.0)]
    space = RankSpace(fams, base_d=2, base_rank=8)
    cands = space.candidates()
    assert cands[0].name == "frozen"
    assert cands[0].d == 2 and cands[0].rank == 8
    names = [c.name for c in cands]
    assert len(names) == len(set(names))
    # frozen's grid twin (d2_r8) must have been dedup'd away
    assert "d2_r8" not in names
    budget = space.param_budget_ratio * cands[0].n_params
    assert all(c.n_params <= budget for c in cands)
    # distinct factorization keys across the grid
    keys = [c._key() for c in cands]
    assert len(keys) == len(set(keys))


def test_rank_space_tight_budget_keeps_frozen():
    fams = [("proj", 64, 64, 1, 1.0)]
    space = RankSpace(fams, base_d=2, base_rank=4, param_budget_ratio=1.0)
    cands = space.candidates()
    assert cands[0].name == "frozen"
    assert all(c.n_params <= cands[0].n_params for c in cands)


def test_rank_space_from_config_matches_tt():
    cfg = get_config("tt-lm-100m", tt=True, smoke=True)
    space = RankSpace.from_config(cfg)
    assert space.base_d == cfg.tt.d
    assert space.base_rank == cfg.tt.rank
    frozen = space.frozen
    assert frozen.compression > 1.0
    assert {f.name for f in frozen.families} >= {"attn.wq", "attn.wk"}


def test_rank_space_rejects_dense_config():
    cfg = get_config("tt-lm-100m", tt=False, smoke=True)
    with pytest.raises(ValueError, match="no tensorized"):
        RankSpace.from_config(cfg)


def test_d1_candidate_is_plain_low_rank():
    fams = [("proj", 24, 18, 1, 1.0)]
    space = RankSpace(fams, base_d=2, base_rank=4, mode_counts=(1,),
                      ladder=(1.0,))
    cands = space.candidates()
    d1 = next(c for c in cands if c.d == 1)
    f = d1.families[0]
    assert f.out_modes == (24,) and f.in_modes == (18,)
    assert f.ranks == (4,)
    # A (24x4) + B (4x18) plus the boundary-rank layout
    assert f.n_params == 1 * 24 * 4 + 4 * 18 * 1


# -- accuracy proxy ----------------------------------------------------


def test_reference_weight_deterministic_and_frozen():
    w1 = reference_weight("attn.wq", 64, 48)
    w2 = reference_weight("attn.wq", 64, 48)
    assert w1 is w2                     # lru cached
    assert w1.shape == (64, 48) and w1.dtype == np.float32
    assert not w1.flags.writeable
    # distinct family names draw distinct spectra
    w3 = reference_weight("mlp.w1", 64, 48)
    assert not np.allclose(w1, w3)


def test_reconstruction_proxy_monotone_in_rank():
    errs = [reconstruction_proxy("attn.wq", 64, 64, (8, 8), (8, 8), r)
            for r in (1, 2, 4, 8, 16)]
    assert all(e >= 0 for e in errs)
    assert all(errs[i] >= errs[i + 1] - 1e-12 for i in range(len(errs) - 1))
    # determinism across calls
    assert errs[0] == reconstruction_proxy(
        "attn.wq", 64, 64, (8, 8), (8, 8), 1)


def test_candidate_proxy_weighting():
    good = FamilyFactorization("a", 64, 64, (64,), (64,), (64,))  # lossless
    bad = FamilyFactorization("b", 64, 64, (64,), (64,), (1,))
    cand = RankCandidate("x", 1, 1, (good, bad))
    base = candidate_proxy(cand)
    upweight_bad = candidate_proxy(cand, weights={"b": 100.0})
    downweight_bad = candidate_proxy(cand, weights={"b": 0.01})
    assert downweight_bad < base < upweight_bad


# -- joint search ------------------------------------------------------


def _small_space(cfg):
    return RankSpace.from_config(cfg, ladder=(0.5, 1.0), mode_counts=(1, 2))


def test_rank_search_smoke_frontier_and_chosen():
    cfg = get_config("tt-lm-100m", tt=True, smoke=True)
    res = rank_search("tt-lm-100m", get_target("fpga_vu9p"), top_k=2,
                      tokens=32, smoke=True, space=_small_space(cfg))
    assert res.evals[res.frozen].candidate.name == "frozen"
    assert res.evals[0].candidate.name == "frozen"
    assert res.frontier, "pareto frontier must be non-empty"
    chosen = res.chosen_eval
    # the chosen candidate respects the default cap (frozen's proxy)
    assert chosen.accuracy_proxy <= res.frozen_eval.accuracy_proxy + 1e-9
    # and is the fastest eligible one
    eligible = [e for e in res.evals
                if e.accuracy_proxy <= res.frozen_eval.accuracy_proxy + 1e-9]
    assert chosen.total_latency_s == min(e.total_latency_s for e in eligible)


def test_rank_search_accuracy_budget_infeasible():
    cfg = get_config("tt-lm-100m", tt=True, smoke=True)
    with pytest.raises(ValueError, match="infeasible"):
        rank_search("tt-lm-100m", get_target("fpga_vu9p"), top_k=2,
                    tokens=32, smoke=True, space=_small_space(cfg),
                    accuracy_budget=1e-9)
    with pytest.raises(ValueError, match="positive"):
        rank_search("tt-lm-100m", get_target("fpga_vu9p"),
                    accuracy_budget=-1.0)


def test_rank_search_frozen_matches_plain_dse():
    """The frozen candidate's joint-search leg must be bit-identical to
    an unsearched run — same tables, same argmin, same total latency."""
    from repro.dse_cli import run_dse

    cfg = get_config("tt-lm-100m", tt=True, smoke=True)
    space = RankSpace.from_config(cfg, ladder=(1.0,),
                                  mode_counts=(cfg.tt.d,))
    res = rank_search("tt-lm-100m", get_target("fpga_vu9p"), top_k=2,
                      tokens=32, smoke=True, space=space)
    report = run_dse("tt-lm-100m", "fpga_vu9p", top_k=2, tokens=32,
                     smoke=True)
    assert res.frozen_eval.total_latency_s == report["total_latency_s"]


# -- CLI plumbing ------------------------------------------------------


def test_run_dse_rank_search_report():
    from repro.dse_cli import run_dse

    report = run_dse("tt-lm-100m", "fpga_vu9p", top_k=2, tokens=32,
                     smoke=True, rank_search="budget")
    rs = report["rank_search"]
    assert rs["mode"] == "budget"
    assert rs["n_candidates"] >= 2
    assert rs["chosen"]["name"] in {c["name"] for c in rs["candidates"]}
    assert rs["plan_embeddable"] is True
    assert rs["chosen"]["families"], "chosen candidate must carry families"
    for fam in rs["chosen"]["families"]:
        assert set(fam) >= {"name", "out_modes", "in_modes", "ranks",
                            "accuracy_proxy"}
    assert report["total_latency_s"] == rs["chosen"]["total_latency_s"]


def test_rank_search_flag_validation():
    from repro.dse_cli import run_dse

    for kwargs, msg in (
        (dict(mode="train"), "rank"),
        (dict(objective="edp"), "rank"),
        (dict(engine="scalar"), "rank"),
        (dict(tune="measure"), "rank"),
    ):
        with pytest.raises(ValueError, match=msg):
            run_dse("tt-lm-100m", "fpga_vu9p", top_k=2, tokens=32,
                    smoke=True, rank_search="budget", **kwargs)
    with pytest.raises(ValueError, match="accuracy_budget"):
        run_dse("tt-lm-100m", "fpga_vu9p", top_k=2, tokens=32,
                smoke=True, accuracy_budget=0.5)


def test_cli_rejects_rank_pair_and_budget_without_rank():
    from repro.dse_cli import main

    with pytest.raises(SystemExit):
        main(["--arch", "tt-lm-100m", "--smoke", "--accuracy-budget", "0.5"])
    with pytest.raises(SystemExit):
        main(["--arch", "tt-lm-100m", "--smoke", "--rank-search", "budget",
              "--emit-plan-pair", "/tmp/x"])


# -- v4 plan embedding -------------------------------------------------


def test_emit_plan_v4_roundtrip(tmp_path):
    from repro.dse_cli import run_dse_plan
    from repro.plan import load_plan

    path = tmp_path / "p.json"
    _, emitted = run_dse_plan("tt-lm-100m", "fpga_vu9p", top_k=2, tokens=32,
                              smoke=True, rank_search="budget")
    path.write_text(emitted.dumps())
    raw = path.read_text()
    plan = load_plan(str(path))
    assert plan.version == 4
    facts = {lp.name: lp.factorization for lp in plan.layers}
    assert any(f is not None for f in facts.values())
    for f in facts.values():
        if f is not None:
            assert len(f.ranks) == len(f.out_modes) + len(f.in_modes) - 1
    # bit-stable round-trip
    assert json.dumps(plan.to_json(), indent=2, sort_keys=True) + "\n" == raw


def test_v3_plan_migrates_to_v4(tmp_path):
    from repro.dse_cli import run_dse_plan
    from repro.plan import load_plan

    _, emitted = run_dse_plan("tt-lm-100m", "fpga_vu9p", top_k=2, tokens=32,
                              smoke=True)
    j = json.loads(emitted.dumps())
    j["version"] = 3
    for layer in j["layers"]:
        layer.pop("factorization", None)
    p3 = tmp_path / "p3.json"
    p3.write_text(json.dumps(j, indent=2, sort_keys=True) + "\n")
    plan = load_plan(str(p3))
    assert plan.version == 4
    assert all(lp.factorization is None for lp in plan.layers)


def test_factorization_schema_validates():
    from repro.plan.schema import Factorization

    with pytest.raises(ValueError, match="interior ranks"):
        Factorization(out_modes=(4, 6), in_modes=(6, 3), ranks=(4,))
    with pytest.raises(ValueError, match="positive ints"):
        Factorization(out_modes=(4, 0), in_modes=(6,), ranks=(4, 4))
    f = Factorization(out_modes=(24,), in_modes=(18,), ranks=(4,),
                      accuracy_proxy=0.25)
    assert f.triple == ((24,), (18,), (4,))


# -- parameter shapes under a factorization ----------------------------


def test_linear_init_under_factorization():
    import jax

    from repro.nn.linear import LinearSpec, TTConfig, linear_apply, linear_init

    tt = TTConfig(enabled=True, d=2, rank=4)
    spec = LinearSpec("proj", d_in=512, d_out=1024, tag="mlp", tt=tt)
    pinned = spec.with_factorization((1024,), (512,), (6,))
    assert pinned.tensorized
    params = linear_init(jax.random.PRNGKey(0), pinned)
    shapes = sorted(v.shape for v in params.values())
    # degenerate TT: two cores (out then in mode), boundary ranks squeezed
    assert shapes == [(6, 512), (1024, 6)]
    x = jax.numpy.ones((2, 512))
    y = linear_apply(pinned, params, x)
    assert y.shape == (2, 1024)
    assert bool(jax.numpy.isfinite(y).all())


def test_plan_context_restores_factorizations(tmp_path):
    from repro.dse_cli import run_dse_plan
    from repro.nn import installed_factorizations, plan_context
    from repro.plan import load_plan

    path = tmp_path / "p.json"
    _, emitted = run_dse_plan("tt-lm-100m", "fpga_vu9p", top_k=2, tokens=32,
                              smoke=True, rank_search="budget")
    path.write_text(emitted.dumps())
    plan = load_plan(str(path))
    assert installed_factorizations() == {}
    with plan_context(plan):
        inner = installed_factorizations()
        assert inner  # the searched decomposition is live
    assert installed_factorizations() == {}


# -- serving pair consistency ------------------------------------------


def _fact_plan(ranks, phase):
    from repro.plan.schema import ExecutionPlan, Factorization, LayerPlan

    lp = LayerPlan(
        name="attn.wq", path_index=0, path_steps=((0, 1), (0, 1)),
        dataflow="OS",
        partitioning=(1, 1), backend="jnp",
        factorization=Factorization(out_modes=(128,), in_modes=(128,),
                                    ranks=(ranks,)))
    return ExecutionPlan(layers=(lp,), arch="tt-lm-100m", hw="fpga_vu9p",
                         strategy="split", phase=phase)


def test_serve_engine_rejects_inconsistent_factorization_pair():
    from repro.serve import ServeEngine

    cfg = get_config("tt-lm-100m", tt=True, smoke=True)
    with pytest.raises(ValueError, match="BOTH phases"):
        ServeEngine(cfg, None, n_slots=1, max_seq=16,
                    prefill_plan=_fact_plan(2, "prefill"))
    with pytest.raises(ValueError, match="different factorizations"):
        ServeEngine(cfg, None, n_slots=1, max_seq=16,
                    prefill_plan=_fact_plan(2, "prefill"),
                    decode_plan=_fact_plan(4, "decode"))


# -- vision ------------------------------------------------------------


def test_vision_rank_space():
    space = vision_rank_space("vit_ti4/cifar10", base_rank=8)
    cands = space.candidates()
    assert cands[0].name == "frozen"
    assert all(c.d == 2 or c.name == "frozen" for c in cands)
    ranks = {c.rank for c in cands}
    assert len(ranks) > 1
