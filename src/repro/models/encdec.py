"""Encoder-decoder backbone (Seamless-M4T medium shape).

The audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, S_enc, D).  The encoder is a
bidirectional transformer over frames; the decoder is a causal LM with
cross-attention into the encoder memory.

Decode caches: per-decoder-layer self-attention KV plus cross-attention
K/V computed once at prefill (static afterwards — the standard serving
structure).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.nn import (
    KVCache,
    LinearSpec,
    attention_apply,
    attention_init,
    embedding_apply,
    embedding_init,
    head_apply,
    init_kv_cache,
    linear_apply,
    linear_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.sharding import shard
from .blocks import attn_spec, mlp_spec
from .config import ModelConfig
from .lm import cross_entropy, embed_spec, head_spec


class EncDecCaches(NamedTuple):
    self_kv: Any       # stacked (L_dec, ...) KVCache
    cross_k: jax.Array  # (L_dec, B, S_enc, H_kv, Dh)
    cross_v: jax.Array


def _xattn_specs(cfg: ModelConfig) -> dict[str, LinearSpec]:
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    kv = cfg.n_kv_heads
    return {
        "wq": LinearSpec("xattn.wq", d, h * hd, False, "attn", cfg.tt),
        "wk": LinearSpec("xattn.wk", d, kv * hd, False, "attn", cfg.tt),
        "wv": LinearSpec("xattn.wv", d, kv * hd, False, "attn", cfg.tt),
        "wo": LinearSpec("xattn.wo", h * hd, d, False, "attn", cfg.tt),
    }


def _enc_block_init(rng, cfg: ModelConfig, dtype) -> dict:
    k1, k2 = jax.random.split(rng)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attention_init(k1, attn_spec(cfg, "enc_attn", causal=False), dtype),
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k2, mlp_spec(cfg, "enc_mlp"), dtype),
    }


def _dec_block_init(rng, cfg: ModelConfig, dtype) -> dict:
    k1, k2, k3 = jax.random.split(rng, 3)
    xs = _xattn_specs(cfg)
    kx = jax.random.split(k2, 4)
    return {
        "ln1": rmsnorm_init(cfg.d_model, dtype),
        "attn": attention_init(k1, attn_spec(cfg, "dec_attn"), dtype),
        "lnx": rmsnorm_init(cfg.d_model, dtype),
        "xattn": {nm: linear_init(kk, xs[nm], dtype) for nm, kk in zip(xs, kx)},
        "ln2": rmsnorm_init(cfg.d_model, dtype),
        "mlp": mlp_init(k3, mlp_spec(cfg, "dec_mlp"), dtype),
    }


def init_params(rng: jax.Array, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(rng, 5)
    enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.n_layers)
    params = {
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(enc_keys),
        "enc_ln_f": rmsnorm_init(cfg.d_model, dtype),
        "embed": embedding_init(ks[2], embed_spec(cfg), dtype),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(dec_keys),
        "ln_f": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = linear_init(ks[3], head_spec(cfg), dtype)
    return params


def _cross_attention(cfg, p, x, mem_k, mem_v):
    """x (B, Sq, D) attends into precomputed memory K/V (B, Sk, Hkv, Dh)."""
    import math as _m
    xs = _xattn_specs(cfg)
    b, sq, _ = x.shape
    h, hd, kv = cfg.n_heads, cfg.hd, cfg.n_kv_heads
    q = linear_apply(xs["wq"], p["wq"], x).reshape(b, sq, h, hd)
    n_rep = h // kv
    if n_rep > 1:
        bb, sk, hh, dd = mem_k.shape
        mem_k = jnp.broadcast_to(mem_k[:, :, :, None, :], (bb, sk, hh, n_rep, dd)
                                 ).reshape(bb, sk, h, dd)
        mem_v = jnp.broadcast_to(mem_v[:, :, :, None, :], (bb, sk, hh, n_rep, dd)
                                 ).reshape(bb, sk, h, dd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        mem_k.astype(jnp.float32)) / _m.sqrt(hd)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(mem_v.dtype), mem_v)
    return linear_apply(xs["wo"], p["wo"], out.reshape(b, sq, h * hd))


def _memory_kv(cfg, p, memory):
    xs = _xattn_specs(cfg)
    b, sk, _ = memory.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    k = linear_apply(xs["wk"], p["wk"], memory).reshape(b, sk, kv, hd)
    v = linear_apply(xs["wv"], p["wv"], memory).reshape(b, sk, kv, hd)
    return k, v


def encode(cfg: ModelConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames (B, S_enc, D) -> encoder memory (B, S_enc, D)."""
    x = shard(frames, "batch", "seq", None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    spec = attn_spec(cfg, "enc_attn", causal=False)

    def body(x, p_l):
        h, _ = attention_apply(spec, p_l["attn"], rmsnorm(p_l["ln1"], x), positions)
        x = x + h
        x = x + mlp_apply(mlp_spec(cfg, "enc_mlp"), p_l["mlp"], rmsnorm(p_l["ln2"], x))
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    else:
        for l in range(cfg.encoder_layers):
            x, _ = body(x, jax.tree.map(lambda a: a[l], params["enc_blocks"]))
    return rmsnorm(params["enc_ln_f"], x)


def _decoder(cfg, params, tokens, memory_kv, caches, cache_pos,
             return_hidden: bool = False):
    """memory_kv: (stacked cross_k, cross_v) per layer OR per-layer compute."""
    x = embedding_apply(embed_spec(cfg), params["embed"], tokens)
    x = shard(x, "batch", "seq", None)
    b, s, _ = x.shape
    base = cache_pos if cache_pos is not None else 0
    positions = jnp.broadcast_to(base + jnp.arange(s)[None, :], (b, s))
    spec = attn_spec(cfg, "dec_attn")
    has_cache = caches is not None

    def body(x, inp):
        p_l, (xk, xv), cache_l = inp
        h, new_cache = attention_apply(
            spec, p_l["attn"], rmsnorm(p_l["ln1"], x), positions, cache_l, cache_pos)
        x = x + h
        x = x + _cross_attention(cfg, p_l["xattn"], rmsnorm(p_l["lnx"], x), xk, xv)
        x = x + mlp_apply(mlp_spec(cfg, "dec_mlp"), p_l["mlp"], rmsnorm(p_l["ln2"], x))
        return x, new_cache

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    xs = (params["dec_blocks"], memory_kv, caches if has_cache else None)
    if cfg.scan_layers:
        x, new_caches = jax.lax.scan(body, x, xs)
    else:
        outs = []
        for l in range(cfg.n_layers):
            x, nc = body(x, jax.tree.map(lambda a: a[l], xs))
            outs.append(nc)
        new_caches = (
            jax.tree.map(lambda *ys: jnp.stack(ys), *outs) if has_cache else None
        )
    x = rmsnorm(params["ln_f"], x)
    if return_hidden:
        return x, (new_caches if has_cache else None)
    logits = _head(cfg, params, x)
    return logits, (new_caches if has_cache else None)


def _head(cfg, params, x):
    if cfg.tie_embeddings:
        logits = head_apply(embed_spec(cfg), params["embed"], x)
    else:
        logits = linear_apply(head_spec(cfg), params["head"], x)
    if logits.ndim == 2:        # chunked-loss path: (tokens, V)
        return shard(logits, "tokens", "model")
    return shard(logits, "batch", None, "model")


def _stacked_memory_kv(cfg, params, memory):
    """Cross K/V for every decoder layer: (L, B, S_enc, Hkv, Dh) x2."""
    def per_layer(p_l):
        return _memory_kv(cfg, p_l["xattn"], memory)
    return jax.vmap(per_layer)(params["dec_blocks"])


def train_loss(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    memory = encode(cfg, params, batch["frontend"])
    mem_kv = _stacked_memory_kv(cfg, params, memory)
    if cfg.loss_chunk:
        from .lm import chunked_cross_entropy
        hidden, _ = _decoder(cfg, params, batch["tokens"], mem_kv, None, None,
                             return_hidden=True)
        return chunked_cross_entropy(
            lambda h: _head(cfg, params, h), hidden, batch["labels"],
            cfg.loss_chunk)
    logits, _ = _decoder(cfg, params, batch["tokens"], mem_kv, None, None)
    return cross_entropy(logits, batch["labels"])


def init_caches(cfg: ModelConfig, batch: int, max_seq: int, enc_len: int,
                dtype=jnp.bfloat16) -> EncDecCaches:
    one = init_kv_cache(attn_spec(cfg, "dec_attn"), batch, max_seq, dtype)
    self_kv = jax.tree.map(
        lambda a: jnp.broadcast_to(a, (cfg.n_layers,) + a.shape), one)
    xk = jnp.zeros((cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.hd), dtype)
    return EncDecCaches(self_kv=self_kv, cross_k=xk, cross_v=xk)


def prefill(cfg: ModelConfig, params: dict, batch: dict, max_seq: int):
    """Encode frames + run the decoder prompt; returns (logits, caches)."""
    b, s = batch["tokens"].shape
    memory = encode(cfg, params, batch["frontend"])
    xk, xv = _stacked_memory_kv(cfg, params, memory)
    self0 = init_caches(cfg, b, max_seq, memory.shape[1], jnp.dtype(cfg.dtype)).self_kv
    logits, self_kv = _decoder(
        cfg, params, batch["tokens"], (xk, xv), self0, jnp.zeros((), jnp.int32))
    return logits[:, -1], EncDecCaches(self_kv, xk.astype(jnp.dtype(cfg.dtype)),
                                       xv.astype(jnp.dtype(cfg.dtype)))


def decode_step(cfg: ModelConfig, params: dict, token: jax.Array,
                caches: EncDecCaches, cache_pos: jax.Array):
    logits, new_self = _decoder(
        cfg, params, token, (caches.cross_k, caches.cross_v),
        caches.self_kv, cache_pos)
    return logits[:, -1], EncDecCaches(new_self, caches.cross_k, caches.cross_v)
