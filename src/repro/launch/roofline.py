"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md Roofline).

Reads ``results/dryrun/*.json`` and derives, per (arch x shape):

  compute_s    = flops_per_device / peak_FLOP/s           (197e12 bf16)
  memory_s     = bytes_per_device / HBM_bw                (819e9 B/s)
  collective_s = collective_bytes_per_device / link_bw    (50e9 B/s)

(cost_analysis / collective parses are per-device under GSPMD, so
dividing by per-chip peaks IS the "global / (chips x peak)" roofline —
verified by calibration.)  Also reports MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) and the useful-compute ratio MODEL_FLOPS / HLO_FLOPs.

Accounting: the dry-run's cost variants are lowered entirely SCAN-FREE
(unrolled layers, unchunked attention and loss), so XLA counts every op
exactly once — no analytic corrections are applied.  The only remaining
approximation is the SSD/WKV inter-chunk state scan (its per-trip FLOPs
are a rescale+add, negligible next to the vectorised chunk GEMMs).
"""

from __future__ import annotations

import argparse
import glob
import json
import math
import os
from typing import Optional

from repro.configs import ARCH_IDS, get_config
from repro.models import SHAPES
from repro.models.lm import count_params  # noqa: F401  (docs reference)

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / link
RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


# ---------------------------------------------------------------------------
# analytic model FLOPs (the 6*N*D yardstick)
# ---------------------------------------------------------------------------

def arch_param_counts(arch: str) -> dict[str, float]:
    """Dense-equivalent and active parameter counts (analytic, no init)."""
    cfg = get_config(arch, tt=False)
    d, f, v, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    hd = cfg.hd
    qk = cfg.n_heads * hd
    kv = cfg.n_kv_heads * hd
    attn = d * qk + 2 * d * kv + qk * d
    mlp3 = 3 * d * f
    embed = v * d
    if cfg.family == "dense" or cfg.family == "vlm":
        per = attn + mlp3
        total = L * per + embed
        active = total
    elif cfg.family == "moe":
        expert = 3 * d * f
        shared = 3 * d * (cfg.moe_shared_d_ff or 0) if cfg.moe_shared else 0
        per = attn + cfg.moe_experts * expert + shared
        per_active = attn + cfg.moe_top_k * expert + shared
        total = L * per + embed
        active = L * per_active + embed
    elif cfg.family == "hybrid":
        d_in = 2 * d
        ssm = d * (2 * d_in + 2 * cfg.ssm_state + d_in // cfg.ssm_head_dim) + d_in * d
        per = ssm + d * f * 0  # zamba2 mamba blocks have no separate MLP here
        n_groups = L // cfg.attn_every if cfg.attn_every else 0
        total = L * per + attn + embed          # ONE shared attention block
        active = L * per + n_groups * attn + embed  # applied n_groups times
    elif cfg.family == "rwkv":
        tm = 5 * d * d + 2 * d * 64 * 5        # projections + lora (approx)
        cm = 2 * d * f + d * d
        per = tm + cm
        total = L * per + embed
        active = total
    elif cfg.family == "encdec":
        enc_per = attn + 2 * d * f             # gelu mlp: up+down
        dec_per = attn + attn + 2 * d * f      # + cross attention
        total = cfg.encoder_layers * enc_per + L * dec_per + embed
        active = total
    else:
        raise ValueError(cfg.family)
    return {"total": float(total), "active": float(active)}


def model_flops(arch: str, shape_name: str) -> float:
    """6*N_active*D for train; 2*N_active*D per generated/processed token
    for inference (forward only)."""
    shape = SHAPES[shape_name]
    counts = arch_param_counts(arch)
    n_active = counts["active"]
    if shape.step == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.step == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n_active * tokens


# ---------------------------------------------------------------------------
# table
# ---------------------------------------------------------------------------

def analyze_cell(result: dict) -> Optional[dict]:
    if result.get("status") != "ok" or "cost" not in result:
        return None
    arch, shape_name = result["arch"], result["shape"]
    n_dev = result["n_devices"]
    flops = result["cost"]["flops_per_device"]
    bytes_ = result["cost"]["bytes_per_device"]
    coll = result["cost"]["collective_bytes_per_device"]
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_ / HBM_BW
    coll_s = coll / LINK_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s), ("collective", coll_s)),
        key=lambda t: t[1],
    )[0]
    mf = model_flops(arch, shape_name) / n_dev
    return {
        "cell": result["cell"],
        "arch": arch,
        "shape": shape_name,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_s": max(compute_s, memory_s, coll_s),
        "bound_fraction": mf / PEAK_FLOPS / max(compute_s, memory_s, coll_s)
        if max(compute_s, memory_s, coll_s) else 0.0,
    }


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| cell | compute (s) | memory (s) | collective (s) | dominant | "
           "MODEL_FLOPs/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} / {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {r['bound_fraction']:.2f} |"
        )
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=RESULTS_DIR)
    ap.add_argument("--pattern", default="*_pod_tt.json")
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    rows = []
    for path in sorted(glob.glob(os.path.join(args.results, args.pattern))):
        with open(path) as f:
            res = json.load(f)
        row = analyze_cell(res)
        if row:
            rows.append(row)
    print(markdown_table(rows))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
